"""Approximation layer (paper §V-C.3): Normal + Lindsay gamma mixture.

Accuracy is judged against the exact log-CF distribution — the paper's own
methodology (Fig. 10 reports relative error of the .95 CI lower end vs the
exact computation)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import approx, poisson_binomial as pb
from repro.core.config import default_float


def _exact_cdf(probs, values):
    f = pb.sum_pgf(jnp.asarray(probs, default_float()),
                   jnp.asarray(values, default_float()))
    return np.cumsum(np.asarray(f.coeffs))


def test_cumulants_match_exact_distribution(rng):
    """kappa_1/kappa_2 from the streaming recursion == mean/var of the
    exact distribution (validates the v_i^j erratum fix)."""
    probs = rng.uniform(0.05, 0.95, 30)
    values = rng.integers(1, 8, 30).astype(float)
    terms = np.asarray(approx.cumulant_terms(
        jnp.asarray(probs, default_float()),
        jnp.asarray(values, default_float()), 8))
    f = pb.sum_pgf(jnp.asarray(probs, default_float()),
                   jnp.asarray(values, default_float()))
    mean = float(f.mean())
    var = float(f.variance())
    assert terms[0] == pytest.approx(mean, rel=1e-10)
    assert terms[1] == pytest.approx(var, rel=1e-10)
    # 3rd central moment == kappa_3
    supp = np.arange(len(np.asarray(f.coeffs)))
    c = np.asarray(f.coeffs)
    mu3 = np.sum(c * (supp - mean) ** 3)
    assert terms[2] == pytest.approx(mu3, rel=1e-8, abs=1e-8)


def test_normal_approximation_cdf_error(rng):
    n = 4000
    probs = rng.uniform(0.1, 0.9, n)
    values = rng.integers(1, 20, n).astype(float)
    na = approx.fit_normal(probs, values)
    cdf = _exact_cdf(probs, values)
    mid = int(na.mu)
    for s in [mid - 200, mid, mid + 200]:
        assert float(na.cdf(s)) == pytest.approx(cdf[s], abs=2e-3)


def test_gamma_mixture_beats_normal_on_skew(rng):
    """Skewed sum (small p): the 3-component mixture tracks the cdf
    tighter than the normal — the reason the paper bothers with it."""
    n = 600
    probs = rng.uniform(0.02, 0.15, n)
    values = rng.integers(1, 25, n).astype(float)
    gm = approx.fit_from_data(probs, values, p=3)
    na = approx.fit_normal(probs, values)
    cdf = _exact_cdf(probs, values)
    grid = np.arange(len(cdf))
    sel = (cdf > 1e-6) & (cdf < 1 - 1e-6)
    err_gm = np.max(np.abs(gm.cdf(grid[sel]) - cdf[sel]))
    err_na = np.max(np.abs(na.cdf(grid[sel]) - cdf[sel]))
    assert err_gm < err_na
    assert err_gm < 5e-3


def test_gamma_mixture_ci_precision(rng):
    """Paper Fig. 10: relative error of the .95 CI lower end vs exact."""
    n = 5000
    probs = rng.uniform(0.1, 0.9, n)
    values = rng.integers(1, 10, n).astype(float)
    gm = approx.fit_from_data(probs, values, p=3)
    cdf = _exact_cdf(probs, values)
    lo_exact = float(np.searchsorted(cdf, 0.025))
    lo_gm, hi_gm = gm.confidence_interval(0.95)
    rel = abs(lo_gm - lo_exact) / lo_exact
    assert rel < 1e-4, rel   # f64 CPU; paper reports 1e-7..1e-9 at 1e8 rows


def test_mixture_handles_negative_values(rng):
    """The 10-sigma shift makes negative-valued sums fittable (§V-C.3)."""
    n = 500
    probs = rng.uniform(0.2, 0.8, n)
    values = rng.integers(-10, 10, n).astype(float)
    gm = approx.fit_from_data(probs, values, p=2)
    mu_true = float(np.sum(probs * values))
    assert gm.mean() == pytest.approx(mu_true, abs=2.0)


def test_moments_from_cumulants_roundtrip():
    kap = np.array([2.0, 3.0, 1.0, 0.5])
    m = approx.moments_from_cumulants(kap)
    # m1 = k1; m2 = k2 + k1^2; m3 = k3 + 3 k2 k1 + k1^3
    assert m[0] == pytest.approx(2.0)
    assert m[1] == pytest.approx(3.0 + 4.0)
    assert m[2] == pytest.approx(1.0 + 3 * 3 * 2 + 8)
