"""Dense PGF value type vs the possible-worlds oracle + property tests on
the polynomial-monoid invariants (paper §IV).

The property tests run twice: under `hypothesis` when it is installed, and
always via seeded `pytest.mark.parametrize` fallbacks so the invariants
stay covered in offline/no-network environments."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pgf as P
from repro.core.config import default_float


def mk(coeffs, offset=0, ppi=0.0, pni=0.0):
    return P.PGF(jnp.asarray(coeffs, default_float()), offset, ppi, pni)


# ----------------------------------------------------------- constructors
def test_bernoulli_sum():
    f = P.PGF.bernoulli(0.7, 3, "SUM")
    np.testing.assert_allclose(np.asarray(f.coeffs),
                               [0.3, 0, 0, 0.7], atol=1e-15)
    assert f.offset == 0


def test_bernoulli_min_carries_inf_mass():
    f = P.PGF.bernoulli(0.7, 5, "MIN")
    assert float(f.p_pos_inf) == pytest.approx(0.3)
    assert float(f.total_mass()) == pytest.approx(1.0)


def test_from_scalar_is_gamma_embedding():
    f = P.PGF.from_scalar(4)
    assert float(f.mass_at(4)) == 1.0


# ------------------------------------------------------------- products
@pytest.mark.parametrize("monoid", ["SUM", "MIN", "MAX"])
def test_pairwise_products_match_possible_worlds(monoid, rng):
    n = 6
    probs = rng.uniform(0.05, 0.95, n)
    values = rng.integers(1, 9, n)
    oracle = P.possible_worlds_pgf(probs, values, monoid)
    acc = P.PGF.bernoulli(probs[0], int(values[0]), monoid)
    for i in range(1, n):
        acc = acc.mul(P.PGF.bernoulli(probs[i], int(values[i]), monoid),
                      monoid)
    for outcome, pr in oracle.items():
        if outcome == np.inf:
            got = float(acc.p_pos_inf)
        elif outcome == -np.inf:
            got = float(acc.p_neg_inf)
        else:
            got = float(acc.mass_at(int(outcome)))
        assert got == pytest.approx(pr, abs=1e-12), (monoid, outcome)


def test_mul_sum_fft_vs_schoolbook(rng):
    a = mk(rng.dirichlet(np.ones(1500)))
    b = mk(rng.dirichlet(np.ones(1400)))
    exact = np.convolve(np.asarray(a.coeffs), np.asarray(b.coeffs))
    viafft = np.asarray(P.fft_convolve(a.coeffs, b.coeffs))
    np.testing.assert_allclose(viafft, exact, atol=1e-12)


def test_product_tree_matches_sequential(rng):
    rows = rng.uniform(0.1, 0.9, (9, 2))
    rows = rows / rows.sum(1, keepdims=True)
    factors = jnp.asarray(rows, default_float())
    tree = P.product_tree(factors)
    seq = mk(rows[0])
    for r in rows[1:]:
        seq = seq.mul_sum(mk(r))
    ct, cs = np.asarray(tree.coeffs), np.asarray(seq.coeffs)
    n = min(len(ct), len(cs))      # tree output is zero-padded wider
    np.testing.assert_allclose(ct[:n], cs[:n], atol=1e-12)
    assert np.all(ct[n:] < 1e-12) and np.all(cs[n:] < 1e-12)


def test_stretch_spreads_coefficients():
    f = mk([0.5, 0.3, 0.2])
    g = f.stretch(3)
    assert g.coeffs.shape[0] == 7
    assert float(g.mass_at(6)) == pytest.approx(0.2)
    assert float(g.mass_at(3)) == pytest.approx(0.3)
    assert float(g.mass_at(1)) == 0.0


def test_truncate_smallest_moves_mass_to_inf():
    f = mk([0.5, 0.3, 0.2])
    g = f.truncate_smallest(2)
    assert float(g.p_pos_inf) == pytest.approx(0.2)
    assert float(g.total_mass()) == pytest.approx(1.0)


# ------------------------------------------------- property-test invariants
# Each invariant is a plain checker; hypothesis (when importable) explores
# the space, and the seeded parametrize fallbacks below always run.
def _check_mass_conservation(p1, p2):
    """Polynomial-monoid closure (Prop. 1): coefficient sums stay 1."""
    a = mk(np.asarray(p1) / np.sum(p1))
    b = mk(np.asarray(p2) / np.sum(p2))
    for prod in (a.mul_sum(b), a.mul_min(b), a.mul_max(b)):
        assert float(prod.total_mass()) == pytest.approx(1.0, abs=1e-9)
        assert np.all(np.asarray(prod.coeffs) >= -1e-12)


def _check_mul_sum_associative_commutative(p1, p2, p3):
    a = mk(np.asarray(p1) / np.sum(p1))
    b = mk(np.asarray(p2) / np.sum(p2))
    c = mk(np.asarray(p3) / np.sum(p3))
    ab_c = a.mul_sum(b).mul_sum(c)
    a_bc = a.mul_sum(b.mul_sum(c))
    ba_c = b.mul_sum(a).mul_sum(c)
    np.testing.assert_allclose(np.asarray(ab_c.coeffs),
                               np.asarray(a_bc.coeffs), atol=1e-9)
    np.testing.assert_allclose(np.asarray(ab_c.coeffs),
                               np.asarray(ba_c.coeffs), atol=1e-9)


def _check_mean_of_count_is_sum_of_probs(ps):
    from repro.core import poisson_binomial as pb
    f = pb.count_pgf(jnp.asarray(ps, default_float()))
    assert float(f.mean()) == pytest.approx(float(np.sum(ps)), abs=1e-8)


def _rand_probs(rng, max_size=8, min_size=1):
    return rng.uniform(0.01, 0.99,
                       int(rng.integers(min_size, max_size + 1))).tolist()


@pytest.mark.parametrize("seed", range(10))
def test_mass_conservation_under_mul_seeded(seed):
    r = np.random.default_rng(seed)
    _check_mass_conservation(_rand_probs(r), _rand_probs(r))


@pytest.mark.parametrize("seed", range(8))
def test_mul_sum_associative_commutative_seeded(seed):
    r = np.random.default_rng(100 + seed)
    _check_mul_sum_associative_commutative(_rand_probs(r), _rand_probs(r),
                                           _rand_probs(r))


@pytest.mark.parametrize("seed", range(8))
def test_mean_of_count_is_sum_of_probs_seeded(seed):
    r = np.random.default_rng(200 + seed)
    _check_mean_of_count_is_sum_of_probs(_rand_probs(r, max_size=10,
                                                     min_size=2))


def test_mass_conservation_under_mul_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    arrays = st.lists(st.floats(0.01, 0.99), min_size=1, max_size=8)
    settings(max_examples=50, deadline=None)(
        given(arrays, arrays)(_check_mass_conservation))()


def test_mul_sum_associative_commutative_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    arrays = st.lists(st.floats(0.01, 0.99), min_size=1, max_size=8)
    settings(max_examples=30, deadline=None)(
        given(arrays, arrays, arrays)(_check_mul_sum_associative_commutative))()


def test_mean_of_count_is_sum_of_probs_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    settings(max_examples=30, deadline=None)(
        given(st.lists(st.floats(0.01, 0.99), min_size=2, max_size=10))(
            _check_mean_of_count_is_sum_of_probs))()


def test_cdf_and_confidence_interval(rng):
    f = mk(rng.dirichlet(np.ones(30)))
    cdf = np.cumsum(np.asarray(f.coeffs))
    for v in [0, 7, 29]:
        assert float(f.cdf(v)) == pytest.approx(cdf[v], abs=1e-12)
    lo, hi = f.confidence_interval(0.9)
    assert 0 <= int(lo) <= int(hi) <= 29
    assert float(f.cdf(hi) - f.cdf(lo) + f.mass_at(lo)) >= 0.9 - 1e-9
