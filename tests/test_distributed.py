"""Multi-device behaviour via subprocesses (own XLA_FLAGS, 8 host devices):
shard_map query execution == single-device reference; compressed psum;
elastic mesh degradation."""
import functools

import pytest

from conftest import run_sub as _run_sub

run_sub = functools.partial(_run_sub, devices=8)


def test_distributed_query_step_matches_reference():
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.db import distributed as dist
from repro.core import poisson_binomial as pb
from repro.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
n, G, F = 4096, 64, 512
rng = np.random.default_rng(0)
p = rng.uniform(0.01, 0.99, n).astype(np.float32)
v = rng.integers(0, 4, n).astype(np.float32)
g = rng.integers(0, G, n).astype(np.int32)
step = dist.make_query_step(mesh, max_groups=G, num_freq=F)
pd, vd, gd = dist.shard_columns(mesh, (jnp.asarray(p), jnp.asarray(v), jnp.asarray(g)))
conf, normal, cum, coeffs = jax.block_until_ready(step(pd, vd, gd))
la, an = pb.logcf_terms(jnp.asarray(p), jnp.asarray(v), F)
ref = pb.logcf_finalize(la, an)
assert float(jnp.max(jnp.abs(coeffs - ref))) < 1e-5
ref_conf = 1 - np.exp(np.bincount(g, np.log1p(-p), G))
assert float(jnp.max(jnp.abs(conf - ref_conf))) < 1e-5
mu_ref = np.bincount(g, v * p, G)
assert float(jnp.max(jnp.abs(normal[:, 0] - mu_ref))) < 1e-3
print("OK")
""")
    assert "OK" in out


def test_compressed_psum_under_shard_map():
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.train.optimizer import compressed_psum
from repro.compat import make_mesh
mesh = make_mesh((8,), ("pod",))
g = jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 256)), jnp.float32)
err = jnp.zeros_like(g)
def f(gs, es):
    avg, new_err = compressed_psum(gs[0], es[0], "pod")
    return avg[None], new_err[None]
fn = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
               out_specs=(P("pod"), P("pod")), check_vma=False)
avg, new_err = fn(g, err)
true_sum = g.mean(0)
# every shard's decompressed average approximates the true mean
rel = float(jnp.abs(avg[0] - true_sum).max() / (jnp.abs(true_sum).max()))
assert rel < 0.05, rel
print("OK")
""")
    assert "OK" in out


def test_elastic_degrade_mesh():
    out = run_sub("""
import jax
from repro.train.elastic import degrade_mesh, scale_batch
# full fleet: 8 devices -> (2, 4) mesh? model capped at 4
m = degrade_mesh(jax.devices(), prefer_model=4)
assert m.shape["model"] == 4 and m.shape["data"] == 2, dict(m.shape)
# lose 3 devices -> 5 usable -> (1, 4) with 1 dropped
m2 = degrade_mesh(jax.devices()[:5], prefer_model=4)
assert m2.shape["model"] == 4 and m2.shape["data"] == 1, dict(m2.shape)
assert scale_batch(64, m) == 32
print("OK")
""")
    assert "OK" in out


def test_sharded_train_step_runs_and_matches_single_device():
    """A reduced-arch train step under a 4x2 mesh with the production
    sharding rules == the same step on one device."""
    out = run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_reduced
from repro.models import api
from repro.sharding import Rules
from repro.train.optimizer import AdamW
from repro.train.trainer import make_train_step
cfg = get_reduced("yi_6b")
from repro.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
rules = Rules(mesh, fsdp=True)
opt = AdamW(lr=1e-2, warmup=1)
params = api.init_params(cfg, jax.random.PRNGKey(0))
state = opt.init(params)
key = jax.random.PRNGKey(1)
batch = dict(tokens=jax.random.randint(key, (8, 16), 0, cfg.vocab_size),
             labels=jax.random.randint(key, (8, 16), 0, cfg.vocab_size))
raw = make_train_step(cfg, opt, accum=1, donate=False, jit=False)
def fn(p, s, b):
    with rules.activate():
        return raw(p, s, b)
shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
psh = rules.params_tree(shapes)
params_sharded = jax.tree.map(jax.device_put, params, psh)
with mesh:
    p2, s2, m2 = jax.jit(fn)(params_sharded, state, batch)
p1, s1, m1 = jax.jit(fn)(params, state, batch)
d = max(float(jnp.abs(jnp.asarray(a, jnp.float32) - jnp.asarray(b, jnp.float32)).max())
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert d < 2e-5, d
print("OK", float(m1["loss"]))
""")
    assert "OK" in out
