"""The query-serving layer: structural plan keys, the bounded LRU plan
cache, and batched parameterized sweeps (db/serving.py, db/plans.py).

The contracts under test:

* ``plan_key`` is STRUCTURAL — two independently constructed, identical
  plans share a key (through lambdas: bytecode + captured constants),
  explicit-default arguments don't change it, and different captured
  constants do;
* a plan-cache hit returns results BIT-IDENTICAL to the cold compile on
  every execution path (resident, streamed, mesh) — every comparison is
  exact equality, never allclose;
* compiling more distinct plans than the cache capacity EVICTS — the
  live-executable population stays flat (the accretion-segfault guard),
  for both the serving cache and the streamed executor's wave cache;
* a batched N-point sweep (default scan mode) is bit-equal per point to
  N sequential runs of the family's jitted executable, regardless of
  chunking.
"""
import gc
import weakref

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.db import serving, tpch
from repro.db.plans import (GroupAgg, LRUCache, Scan, Select, compile_plan,
                            plan_key, plan_params, set_wave_cache_capacity,
                            wave_cache_info)
from repro.db.serving import PlanCache, QueryService

pytestmark = pytest.mark.serving


@pytest.fixture(autouse=True)
def _bounded_compile_cache():
    # Serving tests compile many distinct plans on purpose; keep the
    # single-process suite's compiler footprint flat afterwards.
    yield
    jax.clear_caches()


def _db():
    return tpch.generate(n_orders=48, lines_per_order=4, n_parts=24,
                         n_suppliers=8, n_customers=24, seed=0)


def _assert_biteq(name, ref, got):
    la, ta = jax.tree.flatten(ref)
    lb, tb = jax.tree.flatten(got)
    assert str(ta) == str(tb), (name, str(ta), str(tb))
    for i, (a, b) in enumerate(zip(la, lb)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape and a.dtype == b.dtype, (name, i)
        if not np.array_equal(a, b):
            f = a.astype(np.float64, copy=False)
            g = b.astype(np.float64, copy=False)
            assert ((a == b) | (np.isnan(f) & np.isnan(g))).all(), (name, i)


# ------------------------------------------------------ structural plan keys
class TestPlanKey:
    def test_fresh_identical_plans_share_key(self):
        # Every serving plan, constructed twice from scratch: the keys
        # must match even though the lambdas are distinct objects.
        a = tpch.serving_plans()
        b = tpch.serving_plans()
        for name in a:
            assert plan_key(a[name]) == plan_key(b[name]), name

    def test_explicit_defaults_share_key(self):
        # Golden against default-argument drift: passing the defaults
        # explicitly is the same plan.
        assert plan_key(tpch.q3_plan()) == plan_key(
            tpch.q3_plan(segment=1, max_groups=512, order_join_budget=None))
        assert plan_key(tpch.q18_plan()) == plan_key(
            tpch.q18_plan(qty_threshold=150.0, max_groups=2048))

    def test_keyword_order_shares_key(self):
        # Golden against field reordering at the construction site.
        a = GroupAgg(child=Scan("lineitem"), keys=("l_returnflag",),
                     value="l_quantity", agg="SUM", max_groups=8)
        b = GroupAgg(max_groups=8, agg="SUM", value="l_quantity",
                     keys=("l_returnflag",), child=Scan("lineitem"))
        assert plan_key(a) == plan_key(b)

    def test_captured_constants_differ(self):
        def sel(lim):
            return Select(Scan("lineitem"), lambda t: t["l_quantity"] < lim)

        assert plan_key(sel(10.0)) == plan_key(sel(10.0))
        assert plan_key(sel(10.0)) != plan_key(sel(11.0))

    def test_predicate_logic_differs(self):
        a = Select(Scan("lineitem"), lambda t: t["l_quantity"] < 10.0)
        b = Select(Scan("lineitem"), lambda t: t["l_quantity"] > 10.0)
        assert plan_key(a) != plan_key(b)

    def test_family_params_discovered(self):
        assert plan_params(tpch.q6_family()) == {"disc_lo", "disc_hi",
                                                 "qty_lim"}
        assert plan_params(tpch.q18_family()) == {"qty_threshold"}
        assert plan_params(tpch.q6_plan()) == set()


# ----------------------------------------------------------- LRU primitives
class TestLRUCache:
    def test_eviction_order_and_counters(self):
        dropped = []
        c = LRUCache(2, on_evict=dropped.append)
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1          # refresh a: b is now LRU
        c.put("c", 3)
        assert dropped == [2]
        assert c.get("b") is None
        assert c.get("a") == 1 and c.get("c") == 3
        info = c.info()
        assert info["size"] == 2 and info["evictions"] == 1
        assert info["hits"] == 3 and info["misses"] == 1

    def test_set_capacity_trims(self):
        dropped = []
        c = LRUCache(4, on_evict=dropped.append)
        for i in range(4):
            c.put(i, i)
        c.set_capacity(1)
        assert len(c) == 1 and dropped == [0, 1, 2]
        with pytest.raises(ValueError):
            c.set_capacity(0)

    def test_clear_runs_evict_hook(self):
        dropped = []
        c = LRUCache(4, on_evict=dropped.append)
        c.put("a", 1)
        c.put("b", 2)
        c.clear()
        assert sorted(dropped) == [1, 2] and len(c) == 0


# ------------------------------------------------- bounded wave-cache guard
@pytest.mark.outofcore
def test_wave_cache_bounded():
    """Compiling more distinct STREAMED plans than the wave-cache
    capacity keeps the cache flat and counts evictions (the unbounded
    `_wave_cache` accretion this PR removes)."""
    tables = _db().tables()
    old = set_wave_cache_capacity(3)
    try:
        base = wave_cache_info()["evictions"]
        for c in range(7):
            lim = float(10 + c)
            root = GroupAgg(
                Select(Scan("lineitem"),
                       (lambda t, lim=lim: t["l_quantity"] < lim)),
                ("l_returnflag",), "l_quantity", "SUM", 8)
            compile_plan(root, device_row_budget=64)(tables)
        info = wave_cache_info()
        assert info["size"] <= 3
        assert info["evictions"] - base >= 4
    finally:
        set_wave_cache_capacity(old)


def test_plan_cache_bounded_and_entries_die():
    """2x-capacity distinct plans through the serving cache: size stays
    at capacity and the evicted entries (holding the compiled
    executables) become garbage."""
    tables = _db().tables()
    svc = QueryService(tables, capacity=2)
    refs = []
    for c in range(4):
        lim = float(10 + c)
        root = GroupAgg(
            Select(Scan("lineitem"),
                   (lambda t, lim=lim: t["l_quantity"] < lim)),
            ("l_returnflag",), "l_quantity", "SUM", 8)
        svc.submit(root)
        entry, hit = svc.cache.entry(root, None, jit=True)
        assert hit
        refs.append(weakref.ref(entry))
    info = svc.cache.info()
    assert info["size"] == 2 and info["evictions"] >= 2
    del entry
    gc.collect()
    dead = sum(r() is None for r in refs)
    assert dead >= 2, f"evicted cache entries still alive ({dead}/4 dead)"


# ------------------------------------------------ cache-hit bit-equality
class TestCacheHitBiteq:
    def test_resident_all_queries(self):
        tables = _db().tables()
        svc = QueryService(tables, capacity=16)
        plans_a = tpch.serving_plans()
        cold = {}
        for name, p in plans_a.items():
            out, info = svc.submit(p)
            assert not info["hit"], name
            cold[name] = out
        # Fresh plan OBJECTS on the warm pass: hits must be structural.
        for name, p in tpch.serving_plans().items():
            out, info = svc.submit(p)
            assert info["hit"], name
            _assert_biteq(name, cold[name], out)

    @pytest.mark.outofcore
    def test_streamed(self):
        tables = _db().tables()
        svc = QueryService(tables, capacity=16, device_row_budget=64)
        cold, i0 = svc.submit(tpch.q1_plan())
        warm, i1 = svc.submit(tpch.q1_plan())
        assert not i0["hit"] and i1["hit"]
        _assert_biteq("q1-streamed", cold, warm)

    @pytest.mark.multidevice
    def test_mesh(self):
        from conftest import run_sub
        out = run_sub('''
import jax, numpy as np
from repro.compat import make_mesh
from repro.core import enable_x64
enable_x64()
from repro.db import tpch
from repro.db.serving import QueryService
mesh = make_mesh((2,), ("data",))
db = tpch.generate(n_orders=48, lines_per_order=4, n_parts=24,
                   n_suppliers=8, n_customers=24, seed=0)
svc = QueryService(db.tables(), mesh, capacity=16)
for name, plan in tpch.serving_plans().items():
    cold, i0 = svc.submit(plan)
    assert not i0["hit"], name
    warm, i1 = svc.submit(plan)
    assert i1["hit"], name
    for a, b in zip(jax.tree.leaves(cold), jax.tree.leaves(warm)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), name
print("BITEQ OK")
''')
        assert "BITEQ OK" in out


# ----------------------------------------------------- parameterized sweeps
class TestSweep:
    def _batches(self, n):
        return [
            ("q6", tpch.q6_family(),
             dict(disc_lo=jnp.full((n,), 5.0), disc_hi=jnp.full((n,), 7.0),
                  qty_lim=jnp.arange(1.0, n + 1.0))),
            ("q18", tpch.q18_family(),
             dict(qty_threshold=jnp.linspace(100.0, 240.0, n))),
        ]

    def test_sweep_biteq_sequential(self):
        tables = _db().tables()
        svc = QueryService(tables, capacity=16)
        n = 6
        for name, fam, batch in self._batches(n):
            out, info = svc.sweep(fam, batch)
            assert info["points"] == n and info["launches"] == 1
            seq = jax.jit(svc.cache.entry(fam, None, jit=False)[0].fn)
            for i in range(n):
                point = {k: v[i] for k, v in batch.items()}
                _assert_biteq(f"{name}[{i}]",
                              seq(tables, point),
                              jax.tree.map(lambda l: l[i], out))

    def test_sweep_chunked_biteq(self):
        tables = _db().tables()
        whole = QueryService(tables, capacity=16)
        chunked = QueryService(tables, capacity=16, batch_row_budget=2000)
        for name, fam, batch in self._batches(6):
            a, ia = whole.sweep(fam, batch)
            b, ib = chunked.sweep(fam, batch)
            assert ia["launches"] == 1 and ib["launches"] > 1
            _assert_biteq(name, a, b)

    def test_resweep_hits_cache(self):
        tables = _db().tables()
        svc = QueryService(tables, capacity=16)
        _, fam, batch = self._batches(4)[0]
        _, i0 = svc.sweep(fam, batch)
        assert not i0["hit"]
        # different N, fresh plan object: still one executable
        _, fam2, batch2 = self._batches(8)[0]
        _, i1 = svc.sweep(fam2, batch2)
        assert i1["hit"]

    def test_vmap_mode_close(self):
        # vmap trades bit-equality for lane parallelism: allclose only.
        tables = _db().tables()
        scan = QueryService(tables, capacity=16)
        vmap = QueryService(tables, capacity=16, sweep_mode="vmap")
        _, fam, batch = self._batches(4)[0]
        a, _ = scan.sweep(fam, batch)
        b, _ = vmap.sweep(fam, batch)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-5, atol=1e-7)

    def test_sweep_validation(self):
        tables = _db().tables()
        svc = QueryService(tables, capacity=16)
        fam = tpch.q6_family()
        good = dict(disc_lo=jnp.zeros((4,)), disc_hi=jnp.ones((4,)),
                    qty_lim=jnp.ones((4,)))
        with pytest.raises(ValueError, match="param_batch"):
            svc.sweep(fam, {k: good[k] for k in ("disc_lo", "disc_hi")})
        with pytest.raises(ValueError, match="param_batch"):
            svc.sweep(fam, {**good, "qty_lim": jnp.ones((3,))})
        with pytest.raises(ValueError, match="parameterized"):
            svc.sweep(tpch.q6_plan(), good)
        with pytest.raises(NotImplementedError):
            svc.sweep(fam, good, device_row_budget=64)
        with pytest.raises(ValueError, match="sweep_mode"):
            QueryService(tables, sweep_mode="loop")

    def test_submit_param_validation(self):
        tables = _db().tables()
        fn = compile_plan(tpch.q6_family())
        with pytest.raises(ValueError, match="parameters mismatch"):
            fn(tables)                                # all params missing
        with pytest.raises(ValueError, match="parameters mismatch"):
            fn(tables, dict(disc_lo=5.0, disc_hi=7.0, qty_lim=24.0,
                            extra=1.0))


# ------------------------------------------------------------ service stats
def test_serving_stats_counters():
    tables = _db().tables()
    svc = QueryService(tables, capacity=16)
    p = tpch.q6_plan()
    svc.submit(p)
    svc.submit(p)
    svc.sweep(tpch.q6_family(),
              dict(disc_lo=jnp.full((4,), 5.0), disc_hi=jnp.full((4,), 7.0),
                   qty_lim=jnp.arange(1.0, 5.0)))
    s = svc.stats.as_dict()
    # requests counts submits AND sweeps; the sweep's first compile is a
    # miss, the second submit a hit.
    assert s["requests"] == 3 and s["cache_hits"] == 1
    assert s["batched_requests"] == 1 and s["batched_points"] == 4
    assert s["hit_rate"] == pytest.approx(1 / 3, abs=1e-3)
